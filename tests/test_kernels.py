"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp ref oracles,
over shape/dtype sweeps (hypothesis + parametrize)."""
import math

import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.graph import generators as G
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.intersect.ops import intersect_count
from repro.kernels.intersect.ref import intersect_count_ref
from repro.kernels.segsum.ops import sorted_segment_sum
from repro.kernels.segsum.ref import sorted_segment_sum_ref


# -- intersect ---------------------------------------------------------------

@pytest.mark.parametrize("n_pairs,block_n", [(100, 128), (700, 256),
                                             (513, 512)])
def test_intersect_kernel_shapes(n_pairs, block_n):
    g = G.erdos_renyi(60, 0.25, seed=4)
    rp = np.asarray(g.row_ptr)
    rng = np.random.default_rng(n_pairs)
    a = rng.integers(0, 60, n_pairs)
    b = rng.integers(0, 60, n_pairs)
    ns = max(1, math.ceil(math.log2(g.max_degree + 1)))
    args = (g.col_idx, jnp.asarray(rp[a]), jnp.asarray(rp[a + 1]),
            jnp.asarray(rp[b]), jnp.asarray(rp[b + 1]))
    ref = intersect_count_ref(*args, max_deg=g.max_degree, n_steps=ns)
    got = intersect_count(*args, max_deg=g.max_degree, n_steps=ns,
                          block_n=block_n, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


@given(seed=st.integers(0, 30), n=st.integers(8, 50), p=st.floats(0.1, 0.5))
@settings(max_examples=10, deadline=None)
def test_intersect_kernel_property(seed, n, p):
    g = G.erdos_renyi(n, p, seed=seed)
    if g.n_edges == 0:
        return
    rp = np.asarray(g.row_ptr)
    rng = np.random.default_rng(seed)
    a = rng.integers(0, n, 130)
    b = rng.integers(0, n, 130)
    ns = max(1, math.ceil(math.log2(g.max_degree + 1)))
    args = (g.col_idx, jnp.asarray(rp[a]), jnp.asarray(rp[a + 1]),
            jnp.asarray(rp[b]), jnp.asarray(rp[b + 1]))
    ref = intersect_count_ref(*args, max_deg=g.max_degree, n_steps=ns)
    got = intersect_count(*args, max_deg=g.max_degree, n_steps=ns,
                          block_n=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


# -- segment sum -------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 0.15)])
@pytest.mark.parametrize("n,d,s,block_n", [(1000, 64, 37, 256),
                                           (257, 128, 5, 128),
                                           (64, 8, 64, 64)])
def test_segsum_kernel(n, d, s, block_n, dtype, tol):
    rng = np.random.default_rng(n + d)
    data = jnp.asarray(rng.standard_normal((n, d)), dtype)
    seg = jnp.sort(jnp.asarray(rng.integers(0, s, n), jnp.int32))
    ref = sorted_segment_sum_ref(data.astype(jnp.float32), seg, s)
    got = sorted_segment_sum(data, seg, s, block_n=block_n, interpret=True)
    np.testing.assert_allclose(np.asarray(ref),
                               np.asarray(got, dtype=np.float32),
                               atol=tol, rtol=tol)


def test_segsum_unsorted_ok():
    """One-hot matmul formulation is order-agnostic (bonus property)."""
    rng = np.random.default_rng(0)
    data = jnp.asarray(rng.standard_normal((300, 16)), jnp.float32)
    seg = jnp.asarray(rng.integers(0, 11, 300), jnp.int32)
    ref = sorted_segment_sum_ref(data, seg, 11)
    got = sorted_segment_sum(data, seg, 11, block_n=128, interpret=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got), atol=1e-4)


# -- flash attention ---------------------------------------------------------

CASES = [
    # b, hq, hkv, lq, lk, d, causal
    (2, 4, 2, 128, 128, 64, True),       # GQA causal
    (1, 8, 8, 256, 256, 64, True),       # MHA
    (2, 4, 1, 64, 128, 32, False),       # MQA bidirectional
    (1, 2, 2, 128, 384, 64, True),       # lq < lk (chunked prefill)
    (1, 4, 2, 1, 256, 64, True),         # decode: single query
    (2, 4, 4, 64, 256, 128, True),
]


@pytest.mark.parametrize("b,hq,hkv,lq,lk,d,causal", CASES)
def test_flash_pallas_vs_ref(b, hq, hkv, lq, lk, d, causal):
    rng = np.random.default_rng(lq + lk)
    q = jnp.asarray(rng.standard_normal((b, hq, lq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, lk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, lk, d)), jnp.float32)
    ref = attention_ref(q, k, v, causal=causal)
    pal = flash_attention(q, k, v, causal=causal, impl="pallas",
                          interpret=True, block_q=64, block_k=64)
    fj = flash_attention(q, k, v, causal=causal, impl="flash_jnp",
                         block_k=64)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal), atol=2e-5)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(fj), atol=2e-5)


def test_flash_bf16():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    ref = np.asarray(attention_ref(q, k, v), np.float32)
    pal = np.asarray(flash_attention(q, k, v, impl="pallas",
                                     interpret=True), np.float32)
    np.testing.assert_allclose(ref, pal, atol=0.05)


@given(lq=st.sampled_from([64, 128]), lk=st.sampled_from([128, 256]),
       d=st.sampled_from([32, 64]), causal=st.booleans(),
       seed=st.integers(0, 10))
@settings(max_examples=8, deadline=None)
def test_flash_property(lq, lk, d, causal, seed):
    if lq > lk:
        return
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, 2, lq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, lk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, lk, d)), jnp.float32)
    ref = attention_ref(q, k, v, causal=causal)
    pal = flash_attention(q, k, v, causal=causal, impl="pallas",
                          interpret=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(pal), atol=2e-5)
