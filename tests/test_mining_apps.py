"""End-to-end mining correctness vs brute-force oracles (paper's four apps)."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from oracles import clique_count, fsm_supports, motif_counts, triangle_count
from repro.core import (Miner, make_cf_app, make_fsm_app, make_mc_app,
                        make_tc_app, triangle_count_fused)
from repro.graph import generators as G
from repro.graph.csr import to_networkx

INT_MAX = np.iinfo(np.int32).max


# -- Triangle counting -------------------------------------------------------

def test_tc_engine(er_graph, er_nx):
    assert Miner(er_graph, make_tc_app()).run().count == \
        triangle_count(er_nx)


@pytest.mark.parametrize("use_dag,eager", [(True, True), (True, False),
                                           (False, True), (False, False)])
def test_tc_ablation_modes(er_graph, er_nx, use_dag, eager):
    app = make_tc_app(use_dag=use_dag, eager_prune=eager)
    assert Miner(er_graph, app).run().count == triangle_count(er_nx)


def test_tc_fused(er_graph, er_nx):
    assert triangle_count_fused(er_graph) == triangle_count(er_nx)


@given(n=st.integers(5, 25), p=st.floats(0.1, 0.6), seed=st.integers(0, 30))
@settings(max_examples=12, deadline=None)
def test_tc_property(n, p, seed):
    g = G.erdos_renyi(n, p, seed=seed)
    ref = triangle_count(to_networkx(g))
    assert Miner(g, make_tc_app()).run().count == ref
    assert triangle_count_fused(g) == ref


# -- Clique finding ----------------------------------------------------------

@pytest.mark.parametrize("k", [3, 4, 5])
def test_cf(er_graph, er_nx, k):
    assert Miner(er_graph, make_cf_app(k)).run().count == \
        clique_count(er_nx, k)


def test_cf_on_clique_graph():
    g = G.clique(7)
    import math
    for k in (3, 4, 5):
        assert Miner(g, make_cf_app(k)).run().count == math.comb(7, k)


@given(n=st.integers(6, 20), p=st.floats(0.2, 0.7), seed=st.integers(0, 30))
@settings(max_examples=10, deadline=None)
def test_cf4_property(n, p, seed):
    g = G.erdos_renyi(n, p, seed=seed)
    assert Miner(g, make_cf_app(4)).run().count == \
        clique_count(to_networkx(g), 4)


# -- Motif counting ----------------------------------------------------------

def test_mc3(er_graph, er_nx):
    pm = Miner(er_graph, make_mc_app(3)).run().p_map
    ref = motif_counts(er_nx, 3)
    assert pm[0] == ref[0] and pm[1] == ref[1]


@pytest.mark.parametrize("mode", ["memo", "custom", "generic"])
def test_mc4_modes(er_graph, er_nx, mode):
    pm = np.asarray(Miner(er_graph, make_mc_app(4, mode=mode)).run().p_map)
    ref = motif_counts(er_nx, 4)
    if mode == "generic":
        assert sorted(v for v in pm if v > 0) == sorted(ref.values())
    else:
        assert all(int(pm[i]) == ref.get(i, 0) for i in range(6))


def test_mc4_named_graphs():
    # a 4-cycle has exactly one 4-cycle motif and four wedges
    pm = np.asarray(Miner(G.cycle(4), make_mc_app(4)).run().p_map)
    assert pm.tolist() == [0, 0, 1, 0, 0, 0]
    pm3 = np.asarray(Miner(G.star(5), make_mc_app(3)).run().p_map)
    assert pm3.tolist() == [6, 0]  # C(4,2) wedges, no triangle


@given(n=st.integers(6, 16), p=st.floats(0.15, 0.5), seed=st.integers(0, 30))
@settings(max_examples=8, deadline=None)
def test_mc4_property(n, p, seed):
    g = G.erdos_renyi(n, p, seed=seed)
    ref = motif_counts(to_networkx(g), 4)
    pm = np.asarray(Miner(g, make_mc_app(4)).run().p_map)
    assert all(int(pm[i]) == ref.get(i, 0) for i in range(6))


def test_mc5_generic_beyond_paper():
    """5-motif census via generic canonical labeling (120 permutations) —
    beyond the paper's 3/4-motif classifiers."""
    import networkx as nx
    from collections import Counter
    from itertools import combinations

    g = G.erdos_renyi(11, 0.4, seed=13)
    nxg = to_networkx(g)
    classes: list = []
    counts: Counter = Counter()
    for c in combinations(range(11), 5):
        sub = nxg.subgraph(c)
        if not nx.is_connected(sub):
            continue
        for i, rep in enumerate(classes):
            if nx.is_isomorphic(sub, rep):
                counts[i] += 1
                break
        else:
            classes.append(nx.Graph(sub))
            counts[len(classes) - 1] = 1
    r = Miner(g, make_mc_app(5, mode="generic", max_patterns=64)).run()
    ours = sorted(int(v) for v in r.p_map if v > 0)
    assert ours == sorted(counts.values())


# -- Frequent subgraph mining ------------------------------------------------

def test_fsm_paper_fig2():
    """The paper's Fig. 2: blue-red-green chain has MNI min{3,2,1} = 1."""
    g = G.paper_fig2_graph()
    r = Miner(g, make_fsm_app(3, min_support=0, max_patterns=32)).run()
    sup = sorted(int(s) for s, c in zip(r.supports, r.codes)
                 if c != INT_MAX)
    assert sup == fsm_supports(to_networkx(g), 2, 0)
    assert 1 in sup  # the chain's support from the figure


@pytest.mark.parametrize("minsup", [0, 2, 3])
def test_fsm_2edge(labeled_graph, labeled_nx, minsup):
    r = Miner(labeled_graph,
              make_fsm_app(3, min_support=minsup, max_patterns=64)).run()
    ours = sorted(int(s) for s, c in zip(r.supports, r.codes)
                  if c != INT_MAX and s >= minsup)
    assert ours == fsm_supports(labeled_nx, 2, minsup)


@pytest.mark.parametrize("minsup", [2, 3])
def test_fsm_3edge(minsup):
    g = G.erdos_renyi(12, 0.3, seed=7, labels=2)
    r = Miner(g, make_fsm_app(4, min_support=minsup, max_patterns=256)).run()
    ours = sorted(int(s) for s, c in zip(r.supports, r.codes)
                  if c != INT_MAX and s >= minsup)
    assert ours == fsm_supports(to_networkx(g), 3, minsup)


@given(seed=st.integers(0, 40))
@settings(max_examples=8, deadline=None)
def test_fsm_property(seed):
    g = G.erdos_renyi(10, 0.35, seed=seed, labels=2)
    if g.n_edges < 4:
        return
    r = Miner(g, make_fsm_app(3, min_support=2, max_patterns=64)).run()
    ours = sorted(int(s) for s, c in zip(r.supports, r.codes)
                  if c != INT_MAX and s >= 2)
    assert ours == fsm_supports(to_networkx(g), 2, 2)
