"""Hypothesis compatibility layer for bare environments.

The tier-1 suite must run on a box with nothing but pytest + jax installed.
When the real ``hypothesis`` package is available we re-export it verbatim;
otherwise a minimal deterministic fallback provides the small strategy
subset these tests use (integers, floats, booleans, sampled_from, lists),
running each ``@given`` test on ``max_examples`` seeded random draws.
"""
from __future__ import annotations

try:                                           # pragma: no cover
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: rng.choice(options))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                size = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(size)]
            return _Strategy(draw)

    strategies = _Strategies()

    def given(**strategy_kwargs):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(fn, "_max_examples", 10)
                rng = random.Random(f"{fn.__module__}.{fn.__name__}")
                for _ in range(n):
                    drawn = {k: s.draw(rng)
                             for k, s in strategy_kwargs.items()}
                    fn(*args, **kwargs, **drawn)
            # pytest must not mistake the drawn params for fixtures
            sig = inspect.signature(fn)
            left = [p for name, p in sig.parameters.items()
                    if name not in strategy_kwargs]
            wrapper.__signature__ = sig.replace(parameters=left)
            return wrapper
        return deco

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            # @settings sits above @given's wrapper; stash the budget on the
            # innermost function so given() can read it either way.
            target = getattr(fn, "__wrapped__", fn)
            target._max_examples = max_examples
            fn._max_examples = max_examples
            return fn
        return deco
