"""Launch layer: mesh construction, collective-bytes parser, small-mesh
lower+compile of representative cells (the CI-scale version of the
512-device dry-run, in a subprocess with 4 host devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.dryrun import parse_collective_bytes, roofline_terms

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_parse_collective_bytes():
    hlo = textwrap.dedent("""
      %ar = bf16[256,1024]{1,0} all-reduce(bf16[256,1024]{1,0} %x)
      %ag.1 = f32[64,32]{1,0} all-gather(f32[4,32]{1,0} %y)
      ROOT %t = (f32[2,2]{1,0}) tuple(%z)
      %rs = f32[8,128]{1,0} reduce-scatter(f32[64,128]{1,0} %w)
      %cp-start = bf16[16]{0} collective-permute-start(bf16[16]{0} %v)
      %cp-done = bf16[16]{0} collective-permute-done(%cp-start)
    """)
    out = parse_collective_bytes(hlo)
    assert out["all-reduce"]["count"] == 1
    assert out["all-reduce"]["bytes"] == 256 * 1024 * 2
    assert out["all-gather"]["bytes"] == 64 * 32 * 4
    assert out["reduce-scatter"]["bytes"] == 8 * 128 * 4
    assert out["collective-permute"]["count"] == 1   # start only, not done
    assert out["total_bytes"] == (256 * 1024 * 2 + 64 * 32 * 4
                                  + 8 * 128 * 4 + 16 * 2)


def test_roofline_terms():
    t = roofline_terms(flops=197e12, hbm_bytes=819e9, coll_bytes=0,
                       n_chips=1)
    assert abs(t["compute_s"] - 1.0) < 1e-6
    assert abs(t["memory_s"] - 1.0) < 1e-6
    assert t["dominant"] in ("compute_s", "memory_s")
    t2 = roofline_terms(1e12, 1e9, 1e12, 1)
    assert t2["dominant"] == "collective_s"


def test_make_mesh_shapes():
    code = """
import jax
from repro.launch.mesh import make_test_mesh, dp_axes
m = make_test_mesh(2, 2)
assert m.axis_names == ("data", "model")
assert dp_axes(m) == ("data",)
print("OK")
"""
    _run_subprocess(code, devices=4)


@pytest.mark.parametrize("arch,shape", [
    ("qwen3-0.6b", "train_4k"),
    ("deepseek-moe-16b", "decode_32k"),
    ("gat-cora", "full_graph_sm"),
    ("dien", "retrieval_cand"),
])
def test_cell_compiles_on_small_mesh(arch, shape):
    """Lower+compile the SMOKE config of a cell on a real 2x2 mesh —
    validates the sharding rules end-to-end without 512 fake devices."""
    code = f"""
import jax
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import build_cell
mesh = make_test_mesh(2, 2)
cell = build_cell({arch!r}, {shape!r}, mesh=mesh, smoke=True)
with mesh:
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings)
    compiled = jitted.lower(*cell.args).compile()
assert compiled.cost_analysis() is not None
print("OK")
"""
    _run_subprocess(code, devices=4, timeout=900)


def _run_subprocess(code, devices=4, timeout=600):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "OK" in out.stdout
