"""Eager in-kernel pruning: fused extend_pruned vs the composed
extend -> filter -> compact trio (property-based), PackedGraph bitmap
semantics, survivor-scale planning, and plan-cache versioning/eviction."""
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, strategies as st
from repro.core import Miner, MiningPlan, PlanCache, make_cf_app, \
    make_mc_app, make_tc_app
from repro.core.api import make_ctx, resolve_kernel_predicate
from repro.core.embedding_list import init_level0_vertex, materialize
from repro.core.phases import available_backends, get_backend
from repro.core.phases.reference import (_vertex_candidates,
                                         finish_extend_vertex)
from repro.core.plan import PLAN_SCHEMA, bucket_cap
from repro.graph import generators as G
from repro.graph.csr import pack_adjacency, packed_contains
from repro.sparse.intersect import adj_contains

APPS = [("tc", make_tc_app),
        ("3-cf-nodag", lambda: make_cf_app(3, use_dag=False)),
        ("4-cf", lambda: make_cf_app(4)),
        ("3-mc", lambda: make_mc_app(3)),
        ("4-mc", lambda: make_mc_app(4))]


# -- PackedGraph -------------------------------------------------------------

def test_packed_contains_matches_binary_search():
    g = G.erdos_renyi(60, 0.15, seed=3)
    pg = pack_adjacency(g)
    assert pg.full and pg.n_packed == g.n_vertices
    rng = np.random.default_rng(0)
    # in-contract inputs: valid vertex ids plus negative padding (-1)
    u = jnp.asarray(rng.integers(-2, 60, 4000), jnp.int32)
    v = jnp.asarray(rng.integers(-2, 60, 4000), jnp.int32)
    ctx = make_ctx(g, pack_bits=False)
    ref = adj_contains(g.row_ptr, g.col_idx, u, v, ctx.n_steps)
    np.testing.assert_array_equal(np.asarray(ref),
                                  np.asarray(packed_contains(pg, u, v)))


def test_partial_pack_falls_back_to_csr():
    g = G.erdos_renyi(80, 0.1, seed=4)
    n_words = -(-g.n_vertices // 32)
    pg = pack_adjacency(g, max_bytes=10 * n_words * 4)  # 10 rows only
    assert not pg.full and pg.n_packed == 10
    # packed rows are the highest-degree vertices
    deg = np.asarray(g.degrees())
    packed_rows = np.flatnonzero(np.asarray(pg.row_slot) >= 0)
    assert deg[packed_rows].min() >= np.sort(deg)[-10:].min()
    ctx = make_ctx(g, pack_max_bytes=10 * n_words * 4, pack_partial=True)
    rng = np.random.default_rng(1)
    u = jnp.asarray(rng.integers(0, 80, 2000), jnp.int32)
    v = jnp.asarray(rng.integers(0, 80, 2000), jnp.int32)
    ref = adj_contains(g.row_ptr, g.col_idx, u, v, ctx.n_steps)
    np.testing.assert_array_equal(np.asarray(ref),
                                  np.asarray(ctx.is_connected(u, v)))


def test_mixed_partial_pack_in_pruned_kernel():
    """Power-law graph whose pack budget only covers the high-degree
    rows: the pruned pallas kernel must take the mixed path (bitmap for
    packed rows, CSR binary search for the tail) and still count exactly
    what the reference backend counts."""
    from repro.core import Pattern, pattern_app

    g = G.rmat(7, edge_factor=6, seed=3)           # 128 vertices, power-law
    n_words = -(-g.n_vertices // 32)
    budget = 20 * n_words * 4                      # ~20 hub rows only
    for make in (lambda: make_cf_app(4, use_dag=False),
                 lambda: pattern_app(Pattern.named("diamond"))):
        ref = Miner(g, make()).run().count
        m = Miner(g, make(), backend="pallas", pack_max_bytes=budget,
                  pack_partial=True)
        assert m.ctx.packed is not None and not m.ctx.packed.full
        assert m.ctx.packed.n_packed < g.n_vertices
        assert m.run().count == ref


def test_linear_search_ablation_skips_packing():
    g = G.erdos_renyi(20, 0.3, seed=1)
    assert make_ctx(g, search="linear").packed is None
    assert make_ctx(g, pack_bits=False).packed is None
    assert make_ctx(g).packed is not None
    # partial packs are opt-in: probing both bitmap and CSR fallback per
    # element is a pessimization without a packed-row-aware consumer
    n_words = -(-g.n_vertices // 32)
    assert make_ctx(g, pack_max_bytes=4 * n_words * 4).packed is None
    partial = make_ctx(g, pack_max_bytes=4 * n_words * 4,
                       pack_partial=True).packed
    assert partial is not None and not partial.full


# -- fused extend_pruned == extend -> filter -> compact (property-based) -----

def _level1_inputs(g, app, backend):
    m = Miner(g, app, backend=backend)
    src, dst = m.init_edges()
    n = int(src.shape[0])
    levels = init_level0_vertex(src, dst, n)
    emb = materialize(levels)
    state = (app.init_state(m.ctx, emb, jnp.int32(n))
             if app.init_state is not None
             else jnp.zeros(emb.shape[:1], jnp.int32))
    return m, emb, jnp.int32(n), state


def _composed_trio(ctx, app, emb, n, state, cand_cap, out_cap):
    """The pre-fusion pipeline: materialize all candidates, then filter,
    then compact — composed from the reference ops."""
    row, u, _, add, total = _vertex_candidates(ctx, app, emb, n, state,
                                               cand_cap)
    level, new_emb = finish_extend_vertex(emb, row, u, add, out_cap,
                                          fuse_filter=False)
    return level, new_emb, total


@given(seed=st.integers(0, 1000), n=st.integers(10, 36),
       p=st.sampled_from([0.15, 0.25, 0.4]),
       app_idx=st.integers(0, len(APPS) - 1),
       backend=st.sampled_from(["reference", "pallas"]))
@settings(max_examples=12, deadline=None)
def test_extend_pruned_equals_composed_trio(seed, n, p, app_idx, backend):
    g = G.erdos_renyi(n, p, seed=seed)
    if g.n_edges == 0:
        return
    app = APPS[app_idx][1]()
    m, emb, nv, state = _level1_inputs(g, app, backend)
    be = m.backend
    cand_cap, out_cap = 2048, 512
    level, new_emb, n_cand = be.extend_pruned(m.ctx, app, emb, nv, state,
                                              cand_cap, out_cap)
    ref_level, ref_emb, ref_cand = _composed_trio(m.ctx, app, emb, nv,
                                                  state, cand_cap, out_cap)
    assert int(n_cand) == int(ref_cand)
    assert int(level.n) == int(ref_level.n)
    np.testing.assert_array_equal(np.asarray(level.vid),
                                  np.asarray(ref_level.vid))
    np.testing.assert_array_equal(np.asarray(level.idx),
                                  np.asarray(ref_level.idx))
    live = np.asarray(level.vid) >= 0
    np.testing.assert_array_equal(np.asarray(new_emb)[live],
                                  np.asarray(ref_emb)[live])


def test_every_registered_backend_serves_extend_pruned(er_graph):
    for name in available_backends():
        be = get_backend(name)
        app = make_tc_app()
        m, emb, nv, state = _level1_inputs(er_graph, app, name)
        level, _, n_cand = be.extend_pruned(m.ctx, app, emb, nv, state,
                                            1024, 256)
        assert int(n_cand) > 0 and int(level.n) > 0


def test_to_add_kernel_only_app_mines_consistently(er_graph):
    """An app supplying ONLY to_add_kernel (the documented fast path)
    must plan and mine with that predicate on both backends — inspection
    and extension resolve the same predicate, so survivor-scale caps
    never trip the hook-drift guard."""
    import dataclasses
    app = dataclasses.replace(make_cf_app(3, use_dag=False),
                              to_add=None, to_add_bits=None)
    assert app.to_add_kernel is not None
    r = Miner(er_graph, app).run().count
    p = Miner(er_graph, app, backend="pallas").run().count
    assert r == p


def test_kernel_predicate_resolution():
    assert resolve_kernel_predicate(make_cf_app(4)) is not None
    # hook-less apps get the default canonical test as a plain callable
    assert resolve_kernel_predicate(make_mc_app(3, mode="memo")) is not None
    # the multi-pattern trie emits per-level predicates: level required
    assert resolve_kernel_predicate(make_mc_app(3), 2) is not None
    with pytest.raises(ValueError, match="per-level"):
        resolve_kernel_predicate(make_mc_app(3))
    import dataclasses
    dag_no_hooks = dataclasses.replace(make_cf_app(3), to_add=None,
                                       to_add_bits=None, to_add_kernel=None)
    assert resolve_kernel_predicate(dag_no_hooks) is None


# -- survivor-scale planning -------------------------------------------------

def test_bucket_cap_is_tighter_than_pow2():
    from repro.core.plan import bucket_pow2
    assert bucket_cap(1500) == 1536 < bucket_pow2(1500) == 2048
    assert bucket_cap(5) == 128                       # floor
    assert bucket_cap(128) == 128 and bucket_cap(129) == 256


def test_planned_out_caps_are_survivor_scale(er_graph):
    """Recorded plans size outputs by exact survivor counts (tight
    128-quantum), not pow2 candidate-scale buckets."""
    m = Miner(er_graph, make_mc_app(3))
    r = m.run()
    (rep,) = m.plan_reports()
    (cand_cap, out_cap), = rep["caps"]
    n_emb = r.count
    assert out_cap == bucket_cap(n_emb)               # tight survivor scale
    assert out_cap <= cand_cap


# -- plan cache: versioning + LRU eviction ------------------------------------

def test_stale_schema_plan_ignored_and_removed(tmp_path):
    cache = PlanCache(str(tmp_path))
    plan = MiningPlan(kind="vertex", caps=((256, 128),), cap0=128,
                      signature="sig0", source="inspect")
    path = cache.put(plan)
    stale = plan.to_json().replace(f'"schema": {PLAN_SCHEMA}',
                                   '"schema": 1')
    with open(path, "w") as f:
        f.write(stale)
    assert cache.get("sig0") is None
    assert not os.path.exists(path)                   # stale entry dropped


def test_corrupt_plan_ignored(tmp_path):
    cache = PlanCache(str(tmp_path))
    with open(os.path.join(str(tmp_path), "bad.json"), "w") as f:
        f.write("{not json")
    assert cache.get("bad") is None


def test_plan_cache_lru_eviction(tmp_path):
    cache = PlanCache(str(tmp_path), max_entries=2)
    plans = [MiningPlan(kind="vertex", caps=((256, 128),), cap0=128,
                        signature=f"sig{i}", source="inspect")
             for i in range(3)]
    now = time.time()
    for i, p in enumerate(plans[:2]):
        path = cache.put(p)
        os.utime(path, (now - 100 + i, now - 100 + i))  # deterministic age
    cache.put(plans[2])                                  # evicts oldest
    assert cache.get("sig0") is None
    assert cache.get("sig1") is not None
    assert cache.get("sig2") is not None
    assert len([f for f in os.listdir(str(tmp_path))
                if f.endswith(".json")]) == 2


def test_plan_roundtrip_carries_current_schema():
    p = MiningPlan(kind="edge", caps=((256, 128),), filter_caps=(128,),
                   cap0=256, signature="s", source="inspect")
    import json
    assert json.loads(p.to_json())["schema"] == PLAN_SCHEMA
    assert MiningPlan.from_json(p.to_json()) == p


# -- packed sharded FSM bitmap ------------------------------------------------

def test_reduce_domain_sharded_packed_matches_dense():
    from repro.core import make_fsm_app
    from repro.core.engine import _EdgePipeline, _PhaseOps, run_level_loop
    from repro.core.phases.reference import (reduce_domain,
                                             reduce_domain_sharded)
    from repro.core.plan import HostCapPolicy

    g = G.erdos_renyi(14, 0.3, seed=5, labels=3)
    app = make_fsm_app(3, min_support=2, max_patterns=64)
    m = Miner(g, app)
    ops = _PhaseOps(m.ctx, app, get_backend("reference"))
    pipe = _EdgePipeline(ops)
    run_level_loop(pipe, HostCapPolicy())
    ref = reduce_domain(m.ctx, app, pipe.levels)
    packed = reduce_domain_sharded(m.ctx, app, pipe.levels, (), packed=True)
    dense = reduce_domain_sharded(m.ctx, app, pipe.levels, (), packed=False)
    for a, b, c in zip(ref, packed, dense):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


# -- launch CLI knobs ----------------------------------------------------------

def test_mine_cli_plan_cache_max(tmp_path, capsys):
    from repro.launch.mine import main
    main(["--app", "tc", "--graph", "er:30,0.2", "--plan-cache",
          str(tmp_path), "--plan-cache-max", "4", "--repeat", "2"])
    out = capsys.readouterr().out
    assert "out_cap_total=" in out
    assert any(f.endswith(".json") for f in os.listdir(str(tmp_path)))
