"""Pattern compiler: spec/library semantics, compiled matching plans,
pattern_app counts vs the brute-force oracle (property-based, both
backends), plan-cache isolation by pattern hash, the derived motif-table
bound, and the CLI/quickstart surfaces."""
import os
import random

import numpy as np
import pytest

from _hyp import given, settings, strategies as st
from oracles import pattern_count_bruteforce, pattern_count_noninduced
from repro.core import (Miner, Pattern, compile_pattern, make_cf_app,
                        make_cf_app_compiled, make_mc_app,
                        n_connected_patterns, pattern_app, pattern_names)
from repro.core.api import resolve_kernel_predicate
from repro.core.pattern import DIAMOND4, TAILED4
from repro.core.patterns import enumerate_connected_codes, symmetry_break
from repro.core.plan import plan_signature
from repro.graph import generators as G

BACKENDS = ("reference", "pallas", "pallas-mp")


# -- spec / library -----------------------------------------------------------

def test_constructors_and_library():
    assert Pattern.clique(4).n_edges == 6
    assert Pattern.cycle(5).n_edges == 5
    assert Pattern.path(4).n_edges == 3
    assert Pattern.star(5).n_edges == 4
    assert Pattern.from_string("0-1,1-2,0-2").canonical_code() == \
        Pattern.clique(3).canonical_code()
    for name in pattern_names():
        p = Pattern.named(name)
        assert p.is_connected() and 3 <= p.k <= 6


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="self-loop"):
        Pattern.from_edges([(0, 0), (0, 1)])
    with pytest.raises(ValueError, match="disconnected"):
        Pattern.from_edges([(0, 1), (2, 3)])
    with pytest.raises(ValueError, match="3 vertices"):
        Pattern.from_edges([(0, 1)])
    with pytest.raises(ValueError, match="k <= 6"):
        Pattern.path(7)
    with pytest.raises(KeyError, match="unknown pattern"):
        Pattern.named("heptagon")


def test_canonical_code_is_isomorphism_invariant():
    a = Pattern.from_edges([(0, 1), (0, 2), (1, 2), (0, 3), (1, 3)])
    b = Pattern.from_edges([(3, 2), (3, 1), (2, 1), (3, 0), (2, 0)])
    assert a.canonical_code() == b.canonical_code()
    assert a.hash_hex() == b.hash_hex()
    assert a.canonical_code() != Pattern.cycle(4).canonical_code()


def test_labeled_codes_distinguish_labelings():
    p1 = Pattern.from_edges([(0, 1), (1, 2)], labels=[0, 1, 0])
    p2 = Pattern.from_edges([(0, 1), (1, 2)], labels=[1, 0, 0])
    p3 = Pattern.from_edges([(0, 1), (1, 2)], labels=[0, 0, 1])
    assert p1.canonical_code() != p2.canonical_code()   # center label differs
    assert p2.canonical_code() == p3.canonical_code()   # end-label symmetric


# -- compiler invariants ------------------------------------------------------

def test_compiled_plan_invariants():
    for name in pattern_names():
        plan = compile_pattern(Pattern.named(name))
        adj = plan.pattern.adjacency()
        assert adj[0, 1], "level-0 worklist must be a pattern edge"
        seen = {0, 1}
        for lp in plan.levels:
            assert lp.required, "connectivity-first order broken"
            assert lp.anchor in lp.required
            assert set(lp.required) | set(lp.forbidden) == set(
                range(lp.position))
            assert all(j in seen for j in lp.smaller)
            seen.add(lp.position)
        # stabilizer-chain bookkeeping: every constraint is (a < b)
        assert all(a < b for a, b in plan.constraints)


def test_symmetry_break_orbit_product_equals_aut():
    """The product of consumed orbit sizes equals |Aut| (orbit-stabilizer),
    so constraints admit exactly one embedding per automorphism class."""
    for name in ("diamond", "4-clique", "5-cycle", "bowtie", "4-star"):
        p = compile_pattern(Pattern.named(name)).pattern
        constraints, n_aut = symmetry_break(p)
        # replay the chain on the constraint list: group sizes shrink by
        # the orbit size at each pivot
        group = p.automorphisms()
        prod = 1
        while len(group) > 1:
            moved = min(i for i in range(p.k)
                        if any(s[i] != i for s in group))
            orbit = {s[moved] for s in group}
            prod *= len(orbit)
            group = [s for s in group if s[moved] == moved]
        assert prod == n_aut == len(p.automorphisms())


def test_clique_compiles_to_total_order():
    plan = compile_pattern(Pattern.clique(5))
    assert plan.n_automorphisms == 120
    assert plan.first_pair_symmetric
    assert set(plan.constraints) == {(a, b) for a in range(5)
                                    for b in range(a + 1, 5)}


def test_directed_worklist_only_when_asymmetric():
    assert not pattern_app(Pattern.named("diamond")).directed_worklist
    assert not pattern_app(Pattern.clique(4)).directed_worklist
    assert pattern_app(Pattern.named("wedge")).directed_worklist
    assert pattern_app(Pattern.named("tailed-triangle")).directed_worklist


def test_per_level_kernel_predicates_resolve():
    app = pattern_app(Pattern.named("house"))
    assert isinstance(app.to_add_kernel, tuple)
    assert len(app.to_add_kernel) == 3                 # positions 2, 3, 4
    for k in (2, 3, 4):
        assert resolve_kernel_predicate(app, k) is app.to_add_kernel[k - 2]
    with pytest.raises(ValueError, match="per-level"):
        resolve_kernel_predicate(app)
    # no reduce step anywhere: counting is pure extend_pruned
    assert app.get_pattern is None and not app.needs_reduce


# -- counts vs the brute-force oracle ----------------------------------------

GRAPHS = [G.erdos_renyi(26, 0.25, seed=11), G.rmat(5, edge_factor=4, seed=3)]


@pytest.mark.parametrize("name", ["diamond", "5-clique", "house",
                                  "tailed-triangle", "4-cycle", "5-star"])
def test_library_counts_match_oracle_both_backends(name):
    pat = Pattern.named(name)
    for g in GRAPHS:
        expected = pattern_count_bruteforce(g, pat)
        for backend in BACKENDS:
            got = Miner(g, pattern_app(pat), backend=backend).run().count
            assert got == expected, (name, backend, got, expected)


def _random_connected_pattern(seed: int, k: int) -> Pattern:
    rng = random.Random(seed)
    edges = {(rng.randrange(v), v) for v in range(1, k)}  # spanning tree
    for i in range(k):
        for j in range(i + 1, k):
            if rng.random() < 0.4:
                edges.add((i, j))
    return Pattern.from_edges(sorted(edges), k=k,
                              name=f"rand-{k}v-s{seed}")


@given(seed=st.integers(0, 10_000), k=st.integers(3, 5),
       n=st.integers(10, 20), p=st.sampled_from([0.2, 0.3, 0.45]),
       backend=st.sampled_from(BACKENDS))
@settings(max_examples=10, deadline=None)
def test_random_patterns_match_oracle(seed, k, n, p, backend):
    """Property: for random connected patterns and random graphs, the
    compiled pattern app counts exactly the brute-force induced
    occurrences — on both backends."""
    pat = _random_connected_pattern(seed, k)
    g = G.erdos_renyi(n, p, seed=seed % 97)
    expected = pattern_count_bruteforce(g, pat)
    got = Miner(g, pattern_app(pat), backend=backend).run().count
    assert got == expected, (pat.edges, backend, got, expected)


def test_compiled_clique_parity_with_handwritten(er_graph):
    for k in (3, 4, 5):
        ref = Miner(er_graph, make_cf_app(k)).run().count
        for backend in BACKENDS:
            app = make_cf_app_compiled(k)
            assert Miner(er_graph, app, backend=backend).run().count == ref


def test_compiled_counts_match_motif_histogram(er_graph):
    pm = np.asarray(Miner(er_graph, make_mc_app(4)).run().p_map)
    diamond = Miner(er_graph,
                    pattern_app(Pattern.named("diamond"))).run().count
    tailed = Miner(er_graph,
                   pattern_app(Pattern.named("tailed-triangle"))).run().count
    assert diamond == int(pm[DIAMOND4])
    assert tailed == int(pm[TAILED4])


def test_noninduced_counts():
    # every 4-subset of K5 hosts three non-induced 4-cycles
    g = G.clique(5)
    pat = Pattern.cycle(4)
    app = pattern_app(pat, induced=False)
    assert Miner(g, app).run().count == pattern_count_noninduced(g, pat) \
        == 15
    # induced 4-cycles in a clique: none
    assert Miner(g, pattern_app(pat)).run().count == 0


@pytest.mark.parametrize("name", ["4-path", "tailed-triangle", "house",
                                  "4-star"])
def test_noninduced_counts_stay_injective(name):
    """Non-induced matching drops the forbidden connectivity masks but
    must stay an injective mapping: patterns whose non-adjacent slot
    pairs carry no symmetry constraint would otherwise admit degenerate
    embeddings that reuse a vertex."""
    g = G.erdos_renyi(12, 0.3, seed=5)
    pat = Pattern.named(name)
    expected = pattern_count_noninduced(g, pat)
    for backend in BACKENDS:
        app = pattern_app(pat, induced=False)
        got = Miner(g, app, backend=backend).run().count
        assert got == expected, (name, backend, got, expected)


def test_labeled_pattern_on_fig2_graph():
    # the Fig. 2 labeled graph contains four blue-red-green chains
    g = G.paper_fig2_graph()
    chain = Pattern.from_edges([(0, 1), (1, 2)], labels=[0, 1, 2],
                               name="brg-chain")
    expected = pattern_count_bruteforce(g, chain)
    app = pattern_app(chain)
    # labeled patterns compile to in-kernel per-level predicates (label
    # gathers happen inside the fused kernel), not the batch to_add hook
    assert app.to_add is None
    assert isinstance(app.to_add_kernel, tuple)
    assert all(getattr(p, "needs_labels", False) for p in app.to_add_kernel)
    for backend in BACKENDS:
        got = Miner(g, app, backend=backend).run().count
        assert got == expected == 4, backend


# -- plan cache: pattern hash in the signature --------------------------------

def test_same_k_patterns_get_distinct_plan_signatures():
    a, b = pattern_app(Pattern.named("diamond")), \
        pattern_app(Pattern.named("4-cycle"))
    assert a.plan_key != b.plan_key
    assert plan_signature("g0", a, "pallas", 512) != \
        plan_signature("g0", b, "pallas", 512)
    # induced vs non-induced of the SAME pattern must not share either
    c = pattern_app(Pattern.named("diamond"), induced=False)
    assert plan_signature("g0", a, "pallas", 512) != \
        plan_signature("g0", c, "pallas", 512)


def test_pattern_plan_cache_no_cross_contamination(tmp_path, er_graph):
    """Two different same-k patterns mined through one cache dir must
    record two plans, and each warm replay must reproduce its own cold
    count."""
    cold = {}
    for name in ("diamond", "4-cycle"):
        m = Miner(er_graph, pattern_app(Pattern.named(name)))
        cold[name] = m.run(plan_cache=str(tmp_path)).count
    assert len([f for f in os.listdir(tmp_path)
                if f.endswith(".json")]) == 2
    for name in ("diamond", "4-cycle"):
        m = Miner(er_graph, pattern_app(Pattern.named(name)))
        r = m.run(plan_cache=str(tmp_path))
        (rep,) = m.plan_reports()
        assert rep["source"] == "cache"
        assert r.count == cold[name]


def test_warm_executor_replay_matches_cold(er_graph):
    m = Miner(er_graph, pattern_app(Pattern.named("diamond")),
              backend="pallas")
    cold = m.run().count
    m.run()                                  # compiles the plan executor
    warm = m.run().count
    (rep,) = m.plan_reports()
    assert warm == cold and rep["executions"] >= 1


# -- enumeration / derived motif bound ----------------------------------------

def test_connected_graph_enumeration_counts():
    assert [n_connected_patterns(k) for k in (1, 2, 3, 4, 5, 6)] == \
        [1, 1, 2, 6, 21, 112]
    assert len(set(enumerate_connected_codes(5))) == 21


def test_mc_max_patterns_derived_not_guessed():
    assert make_mc_app(5).max_patterns == 21
    assert make_mc_app(6).max_patterns == 112
    with pytest.raises(ValueError, match="max_patterns"):
        make_mc_app(7)
    assert make_mc_app(7, max_patterns=1000).max_patterns == 1000


def test_mc5_census_total_matches_subset_count():
    # all 21 5-motif patterns fit the derived table: census total equals
    # the number of connected 5-subsets (each classified exactly once)
    g = G.erdos_renyi(14, 0.35, seed=4)
    r = Miner(g, make_mc_app(5)).run()
    total = 0
    for name in ("5-clique", "5-cycle", "5-path", "5-star", "house",
                 "bowtie"):
        total += pattern_count_bruteforce(g, Pattern.named(name))
    # the six library 5-patterns are a subset of all 21 classes
    assert int(np.asarray(r.p_map).sum()) >= total


# -- CLI / example surfaces ---------------------------------------------------

def test_mine_cli_pattern_flag(tmp_path, capsys):
    from repro.launch.mine import main
    main(["--pattern", "diamond", "--graph", "er:26,0.25", "--backend",
          "pallas", "--plan-cache", str(tmp_path), "--repeat", "2"])
    out = capsys.readouterr().out
    g = G.erdos_renyi(26, 0.25, seed=0)
    expected = pattern_count_bruteforce(g, Pattern.named("diamond"))
    assert f"count = {expected}" in out
    assert any(f.endswith(".json") for f in os.listdir(tmp_path))


def test_mine_cli_pattern_edges(capsys):
    from repro.launch.mine import main
    main(["--pattern-edges", "0-1,1-2,0-2", "--graph", "er:20,0.3"])
    out = capsys.readouterr().out
    g = G.erdos_renyi(20, 0.3, seed=0)
    expected = pattern_count_bruteforce(g, Pattern.clique(3))
    assert f"count = {expected}" in out


def test_mine_cli_pattern_list(capsys):
    from repro.launch.mine import main
    main(["--pattern", "list"])
    assert "diamond" in capsys.readouterr().out


def test_quickstart_example_smoke(capsys):
    """The quickstart example must run end-to-end on the current API."""
    import quickstart  # noqa: F401  (examples/ on sys.path via conftest)
    quickstart.main(scale=4)
    out = capsys.readouterr().out
    assert "compiled-pattern counts match" in out
